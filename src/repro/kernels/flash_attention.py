"""Flash attention Pallas TPU kernel (causal + sliding-window, GQA-aware).

TPU-native adaptation of the blockwise online-softmax algorithm:

* the grid is (batch, q_head, q_blocks, kv_blocks); on TPU the last grid dim
  iterates sequentially per core, so the running (m, l, acc) state lives in
  VMEM scratch across kv-block steps,
* BlockSpecs tile q/k/v/o as (block_q|block_k, d_head) VMEM slabs — block
  sizes default to 512/512 which keeps the working set
  (2·block·d + block², f32) well under the ~16 MB VMEM budget and keeps the
  MXU matmul dims at multiples of 128,
* fully-masked kv blocks (beyond the causal frontier or the sliding window)
  are skipped with ``pl.when`` — the TPU analogue of warp-level early-exit.

Validated under ``interpret=True`` against ``ref.reference_attention``
(tests/test_kernels.py sweeps shapes, dtypes, GQA ratios, windows).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale, block_q, block_k, n_kv_blocks, causal, window,
                 seq_q, seq_k):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # block-level skip: strictly above the causal diagonal, or entirely
    # behind the sliding window
    relevant = jnp.bool_(True)
    if causal:
        relevant &= k_start <= q_start + block_q - 1
    if window is not None:
        relevant &= k_start + block_k - 1 > q_start - window

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale      # (bq, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)              # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = q @ k.T                                            # (bq, bk)
        qp = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kp = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = (qp < seq_q) & (kp < seq_k)
        if causal:
            ok &= kp <= qp
        if window is not None:
            ok &= kp > qp - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(ok, p, 0.0)          # NEG_INF rows would exp→~0 anyway
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
        m_scr[...] = m_cur

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=None,
                           block_q=512, block_k=512, interpret=False):
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D) with H % KV == 0.
    Returns (B, Sq, H, D) in q.dtype."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    assert H % KV == 0
    group = H // KV
    scale = 1.0 / math.sqrt(D)

    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 8))
    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Sk, block_k)
    Sq_pad, Sk_pad = nq * block_q, nk * block_k
    if Sq_pad != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_pad - Sq), (0, 0), (0, 0)))
    if Sk_pad != Sk:
        k = jnp.pad(k, ((0, 0), (0, Sk_pad - Sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sk_pad - Sk), (0, 0), (0, 0)))

    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_kv_blocks=nk, causal=causal, window=window, seq_q=Sq, seq_k=Sk)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, qi, ki, g=group: (b, ki, h // g, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, qi, ki, g=group: (b, ki, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq_pad, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
