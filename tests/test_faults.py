"""Fault-tolerance suite: injected faults, guard rails, breaker, durability.

The robustness contract, layer by layer:

* fault transforms (``nan_grad`` / ``corrupt_receipt`` / ``worker_crash``
  / ``host_preempt``) lower through the ordinary scenario grammar into
  deterministic ``RunPlan`` channels — an injected-fault run still holds
  scan ≡ eager parity (faults are data, not control flow),
* the trainer's guard rails skip non-finite rounds IN-MASK (the compiled
  program never branches to host) and back a faulty worker's effective
  stepsize off and back via the per-worker health channel,
* the :class:`~repro.faults.DivergenceBreaker` trips through the tap lane
  and stops the executor from launching further chunks,
* :class:`~repro.checkpoint.AsyncSnapshotter` gives the barrier-free
  metric modes (``tap`` / ``none``) periodic durability: a resumed run —
  including one whose writer process was SIGKILLed mid-run — is
  bit-for-bit the uninterrupted run at chunk boundaries.
"""
import os
import signal
import subprocess
import sys
import time

import numpy as np
import jax
import pytest

from repro import checkpoint
from repro.api import ExperimentSpec, TrainJob, TrainerBackend
from repro.checkpoint import AsyncSnapshotter
from repro.faults import (CorruptReceipt, DivergenceBreaker, GuardConfig,
                          HostPreempt, NanGrad, WorkerCrash)
from repro.runtime import METRICS, PlanExecutor, RunPlan, compile_plan
from repro.scenarios import parse_scenario

MICRO = (("n_layers", 1), ("d_model", 64), ("n_heads", 2), ("n_kv_heads", 1),
         ("d_ff", 64), ("vocab", 97))

TOL = dict(rtol=1e-5, atol=1e-7)


def _job(**kw):
    kw.setdefault("arch", "qwen2-0.5b")
    kw.setdefault("global_batch", 8)
    kw.setdefault("seq_len", 16)
    kw.setdefault("arch_overrides", MICRO)
    return TrainJob(**kw)


def _spec(job, T=12, scenario=None, **kw):
    kw.setdefault("stepsize", 3e-3)
    return ExperimentSpec(scheduler="shuffled", timing="poisson:slow=6",
                          objective=job, T=T, n_workers=4, seed=0,
                          scenario=scenario, **kw)


def _trainer(job, guards=None):
    from jax.sharding import Mesh
    from repro.distributed import AsyncTrainer, AsyncConfig
    from repro.optim import OptConfig

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    tr = AsyncTrainer(
        job.make_arch(), mesh,
        opt=OptConfig(lr=3e-3, clip_norm=job.clip_norm,
                      update_impl=job.update_impl),
        async_cfg=AsyncConfig(delay_rounds=job.delay_rounds, guards=guards))
    tr.n_groups = 4
    return tr


def _faulted_plan(job, spec):
    """World + plan for a spec whose scenario carries fault transforms."""
    world = TrainerBackend.world_for(spec, 4)
    plan = compile_plan(world.schedule, job, rounds=spec.T, n_groups=4,
                        seed=spec.seed, availability=world.availability,
                        fault_gain=world.fault_gain)
    return world, plan


def _leaves(tr, state):
    return [np.asarray(x, np.float32) for x in
            jax.tree_util.tree_leaves(tr.params_of(state))]


# ---------------------------------------------------------------------------
# fault transforms: grammar, lowering, validation
# ---------------------------------------------------------------------------
def test_fault_spec_parses_and_lowers_into_plan_channels():
    """The four fault names ride the ordinary scenario grammar and lower
    into fault_gain / availability / preempt_rounds deterministically."""
    spec_str = ("nan_grad:k=1,every=4,span=1;"
                "corrupt_receipt:k=1,scale=1e4,every=6,span=1;"
                "worker_crash:k=1,at=3,span=2;"
                "host_preempt:at=8")
    sc = parse_scenario(spec_str)
    assert sc.names == ("nan_grad", "corrupt_receipt", "worker_crash",
                        "host_preempt")
    job = _job()
    spec = _spec(job, T=12, scenario=spec_str)
    world, plan = _faulted_plan(job, spec)

    g = world.fault_gain
    assert g.shape == (12, 4)
    # windows start at j*every (round 0 stays clean — stationary start)
    assert not np.isnan(g[0]).any() and np.all(g[0] == 1.0)
    nan_rounds = sorted(set(np.where(np.isnan(g).any(axis=1))[0]))
    assert nan_rounds == [4, 8]
    big_rounds = sorted(set(np.where((g > 1.0).any(axis=1))[0]))
    assert big_rounds == [6]
    # worker_crash: one worker down for rounds [3, 5) via availability
    avail = world.availability
    assert avail.shape == (12, 4)
    down = np.where(avail == 0)
    assert sorted(set(down[0])) == [3, 4] and len(set(down[1])) == 1
    assert np.all(plan.masks[avail == 0] == 0.0)    # hard-drop applied
    # host_preempt is host metadata only — never a device channel
    np.testing.assert_array_equal(world.preempt_rounds, [8])
    assert plan.summary()["faulted"]
    # realisation is deterministic: same seed → identical channels
    world2, _ = _faulted_plan(job, spec)
    np.testing.assert_array_equal(world2.fault_gain, g)
    np.testing.assert_array_equal(world2.availability, avail)


def test_fault_transform_and_guard_validation():
    with pytest.raises(ValueError, match="nan_grad"):
        NanGrad(k=0)
    with pytest.raises(ValueError, match="scale"):
        CorruptReceipt(scale=1.0)
    with pytest.raises(ValueError, match="scale"):
        CorruptReceipt(scale=np.inf)
    with pytest.raises(ValueError, match="round 0"):
        WorkerCrash(at=0)
    with pytest.raises(ValueError, match="at"):
        HostPreempt(at=0)
    for bad in (dict(backoff=0.0), dict(backoff=1.0), dict(recover=0.99),
                dict(min_scale=0.0), dict(min_scale=1.5),
                dict(spike_norm=-1.0)):
        with pytest.raises(ValueError):
            GuardConfig(**bad)
    with pytest.raises(ValueError, match="window"):
        DivergenceBreaker(window=0)
    with pytest.raises(ValueError, match="factor"):
        DivergenceBreaker(factor=1.0)
    # plan-level channel validation: zero gain is not a fault model (drop
    # workers via the availability channel), wrong shape is rejected
    job = _job()
    spec = _spec(job, T=4)
    _, schedule = TrainerBackend.masks_for(spec, 4)
    base = compile_plan(schedule, job, rounds=4, n_groups=4, seed=0)
    common = dict(masks=base.masks, delay_scales=base.delay_scales,
                  data_keys=base.data_keys, token_cdf=base.token_cdf,
                  group_perms=base.group_perms, global_batch=8, seq_len=16,
                  seed=0)
    with pytest.raises(ValueError, match="availability"):
        RunPlan(fault_gain=np.zeros((4, 4), np.float32), **common)
    with pytest.raises(ValueError, match="fault_gain"):
        RunPlan(fault_gain=np.ones((3, 4), np.float32), **common)
    assert not base.summary()["faulted"]


def test_divergence_breaker_unit():
    br = DivergenceBreaker(window=3, factor=2.0)
    for i, l in enumerate([1.0, 1.0, 1.0]):       # best window = 1.0
        assert not br.observe(i, l)
    # sliding window [1, 1, 10]: mean 4 > 2 × best(=1) → trips right away
    assert br.observe(5, 10.0)
    assert br.tripped and br.tripped_round == 5
    assert br.observe(8, 1.0)                     # latched


def test_divergence_breaker_trips_on_nonfinite_loss():
    """Regression: NaN compares false against factor×best, so a NaN-only
    divergence used to never trip the breaker — non-finite losses must
    trip immediately, even before a full window has been observed."""
    for bad in (float("nan"), float("inf"), float("-inf")):
        br = DivergenceBreaker(window=8, factor=10.0)
        assert not br.observe(0, 1.0)
        assert br.observe(1, bad), f"breaker ignored loss={bad}"
        assert br.tripped and br.tripped_round == 1
        assert br.observe(2, 1.0)                 # latched


# ---------------------------------------------------------------------------
# guard rails: skip-in-mask, backoff/recovery, scan ≡ eager under faults
# ---------------------------------------------------------------------------
NAN_WORLD = "nan_grad:k=2,every=4,span=1"


def test_guarded_faulted_plan_scan_matches_eager():
    """Injected-fault runs keep the executor contract: the guard is part
    of the compiled step, so scan ≡ eager on every metric — including the
    skipped/gscale guard channels — and the final params agree."""
    job = _job()
    spec = _spec(job, T=12, scenario=NAN_WORLD)
    _, plan = _faulted_plan(job, spec)
    tr = _trainer(job, guards=GuardConfig())
    from repro.runtime import run_eager, run_scan

    r_e = run_eager(tr, plan, tr.init_state(jax.random.PRNGKey(0)))
    r_s = run_scan(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                   rounds_per_launch=5)            # ragged: 5 + 5 + 2
    for k in METRICS:
        np.testing.assert_allclose(r_s.metrics[k], r_e.metrics[k], **TOL,
                                   err_msg=f"faulted metric {k}")
    for a, b in zip(_leaves(tr, r_e.state), _leaves(tr, r_s.state)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)
    # a skip fires exactly when a poisoned worker actually participates
    poisoned = (np.isnan(plan.fault_gain) & (plan.masks > 0)).any(axis=1)
    np.testing.assert_array_equal(r_s.metrics["skipped"],
                                  poisoned.astype(np.float32))
    assert poisoned.any()                          # the world actually bit


def test_guard_skips_poison_where_unguarded_diverges():
    job = _job()
    spec = _spec(job, T=12, scenario=NAN_WORLD)
    _, plan = _faulted_plan(job, spec)
    from repro.runtime import run_scan

    tr_u = _trainer(job)                           # no guards
    r_u = run_scan(tr_u, plan, tr_u.init_state(jax.random.PRNGKey(0)),
                   rounds_per_launch=4)
    assert not all(np.isfinite(l).all() for l in _leaves(tr_u, r_u.state)), \
        "unguarded run should be poisoned by the NaN receipts"
    # unguarded trainers report neutral guard channels
    np.testing.assert_array_equal(r_u.metrics["skipped"], np.zeros(12))
    np.testing.assert_array_equal(r_u.metrics["gscale"], np.ones(12))

    tr_g = _trainer(job, guards=GuardConfig())
    r_g = run_scan(tr_g, plan, tr_g.init_state(jax.random.PRNGKey(0)),
                   rounds_per_launch=4)
    assert all(np.isfinite(l).all() for l in _leaves(tr_g, r_g.state)), \
        "guarded params must stay finite through the same faults"
    # clean-round metrics stay finite; only the skipped rounds report the
    # poisoned (never-applied) loss
    skipped = r_g.metrics["skipped"].astype(bool)
    assert skipped.any()
    assert np.isfinite(r_g.metrics["loss"][~skipped]).all()
    # health backoff is observable: gscale < 1 can only come from a
    # backed-off worker participating again, which happens strictly after
    # the first skip (gscale reports pre-update health, so the skip round
    # itself still shows 1.0)
    gscale = r_g.metrics["gscale"]
    first_skip = int(np.argmax(skipped))
    assert gscale.min() < 1.0
    assert int(np.argmin(gscale)) > first_skip
    np.testing.assert_array_equal(gscale[:first_skip + 1],
                                  np.ones(first_skip + 1))


def test_health_backoff_and_recovery_deterministic():
    """Pin the health dynamics exactly: all workers participate every
    round, worker 0's receipt is poisoned at round 1 only.  Every
    participant of the bad round is charged (health is per-participant —
    blame is not attributable below round granularity), so gscale (the
    participation-weighted mean of pre-round health) follows the shared
    ×0.5 backoff then ×1.25-per-clean-round recovery trajectory."""
    import dataclasses

    job = _job()
    spec = _spec(job, T=8)
    _, schedule = TrainerBackend.masks_for(spec, 4)
    base = compile_plan(schedule, job, rounds=8, n_groups=4, seed=0)
    gain = np.ones((8, 4), np.float32)
    gain[1, 0] = np.nan
    plan = dataclasses.replace(base, masks=np.ones((8, 4), np.float32),
                               fault_gain=gain)
    tr = _trainer(job, guards=GuardConfig())     # backoff .5, recover 1.25
    from repro.runtime import run_scan

    r = run_scan(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                 rounds_per_launch=4)
    np.testing.assert_array_equal(
        r.metrics["skipped"], [0, 1, 0, 0, 0, 0, 0, 0])
    h = [1.0, 1.0, 0.5, 0.625, 0.78125, 0.9765625, 1.0, 1.0]
    np.testing.assert_allclose(
        r.metrics["gscale"], h, rtol=1e-6,
        err_msg="health backoff/recovery trajectory")
    # the per-worker channel lands in the state: everyone fully recovered
    np.testing.assert_allclose(
        np.asarray(r.state["guard"]["health"]), np.ones(4), rtol=1e-6)


def test_guards_are_noop_on_a_clean_world():
    """On a fault-free plan the guard rails must not change the math:
    every metric matches the unguarded trainer bit-for-tolerance, health
    stays at 1, nothing is skipped."""
    job = _job()
    spec = _spec(job, T=6)
    _, schedule = TrainerBackend.masks_for(spec, 4)
    plan = compile_plan(schedule, job, rounds=6, n_groups=4, seed=0)
    from repro.runtime import run_scan

    tr_u = _trainer(job)
    r_u = run_scan(tr_u, plan, tr_u.init_state(jax.random.PRNGKey(0)),
                   rounds_per_launch=3)
    tr_g = _trainer(job, guards=GuardConfig())
    r_g = run_scan(tr_g, plan, tr_g.init_state(jax.random.PRNGKey(0)),
                   rounds_per_launch=3)
    for k in METRICS:
        np.testing.assert_allclose(r_g.metrics[k], r_u.metrics[k], **TOL,
                                   err_msg=f"clean-world metric {k}")
    np.testing.assert_array_equal(r_g.metrics["skipped"], np.zeros(6))
    np.testing.assert_array_equal(r_g.metrics["gscale"], np.ones(6))


# ---------------------------------------------------------------------------
# divergence breaker through the tap lane
# ---------------------------------------------------------------------------
def test_breaker_trips_through_tap_and_truncates_curves():
    """Garbage-but-finite receipts (corrupt_receipt) spike the loss; the
    breaker watching the tap lane trips and the executor stops launching
    — curves cover exactly the rounds actually launched."""
    job = _job()
    spec = _spec(job, T=24, scenario="corrupt_receipt:k=3,scale=1e4,"
                                     "every=4,span=2")
    _, plan = _faulted_plan(job, spec)
    tr = _trainer(job)                             # unguarded: loss spikes
    br = DivergenceBreaker(window=3, factor=5.0)
    ex = PlanExecutor(tr, plan)
    r = ex.run_scan(tr.init_state(jax.random.PRNGKey(0)),
                    rounds_per_launch=4, metrics="tap", breaker=br)
    assert r.stats.tripped_round is not None
    assert br.tripped
    n = len(r.metrics["loss"])
    # truncation: whole chunks only, covering at least the trip round
    assert n % 4 == 0 and r.stats.tripped_round < n <= 24
    assert r.tap_events == n and r.launches == n // 4
    # the spike the breaker saw is real
    assert r.metrics["loss"].max() > 5.0 * r.metrics["loss"].min()
    # breaker is tap-only: chunk/none never stream per-round losses
    with pytest.raises(ValueError, match="tap"):
        ex.run_scan(tr.init_state(jax.random.PRNGKey(0)),
                    metrics="chunk", breaker=DivergenceBreaker())


# ---------------------------------------------------------------------------
# barrier-free durability: async snapshots + resume
# ---------------------------------------------------------------------------
def test_snapshotter_validation_and_cadence():
    with pytest.raises(ValueError, match="cadence"):
        AsyncSnapshotter("/tmp/x", 0)
    with pytest.raises(ValueError, match="keep"):
        AsyncSnapshotter("/tmp/x", 4, keep=0)
    s = AsyncSnapshotter("/tmp/x", 4)
    assert s.due(4, 12) and s.due(8, 12) and s.due(12, 12)
    assert not s.due(6, 12)
    assert s.due(10, 10)                  # final boundary is always due
    assert AsyncSnapshotter.latest("/tmp/definitely-not-a-dir") is None


@pytest.mark.parametrize("metrics", ["none", "tap"])
def test_async_snapshot_resume_is_bitwise_at_chunk_boundary(tmp_path,
                                                            metrics):
    """The fast metric transports get durability with zero mid-run
    barriers: restore the newest snapshot, resume at its boundary, and
    the final state is BIT-FOR-BIT the uninterrupted run's."""
    job = _job()
    spec = _spec(job, T=12)
    _, schedule = TrainerBackend.masks_for(spec, 4)
    plan = compile_plan(schedule, job, rounds=12, n_groups=4, seed=0)
    tr = _trainer(job, guards=GuardConfig())
    ex = PlanExecutor(tr, plan)

    snapdir = str(tmp_path / f"snaps-{metrics}")
    snap = AsyncSnapshotter(snapdir, 4, keep=2, meta={"arch": "micro"})
    full = ex.run_scan(tr.init_state(jax.random.PRNGKey(0)),
                       rounds_per_launch=4, metrics=metrics, snapshot=snap)
    assert full.stats.snapshots == 3              # boundaries 4, 8, 12
    assert full.stats.host_syncs == 0             # still barrier-free
    # keep=2 pruning: only the newest two survive
    dirs = sorted(d for d in os.listdir(snapdir) if d.startswith("round-"))
    assert dirs == ["round-00000008", "round-00000012"]
    r, latest = AsyncSnapshotter.latest(snapdir)
    assert r == 12 and latest.endswith("round-00000012")
    meta = checkpoint.load_meta(latest)
    assert meta["kind"] == "snapshot" and meta["round"] == 12
    assert meta["arch"] == "micro"

    # resume from the MID-RUN snapshot (round 8), not the final one
    restored = checkpoint.restore(os.path.join(snapdir, "round-00000008"),
                                  tr.abstract_state(),
                                  shardings=tr.state_shardings())
    assert int(restored["step"]) == 8
    tail = ex.run_scan(restored, rounds_per_launch=4, metrics=metrics,
                       start_round=8)
    assert tail.launches == 1
    for a, b in zip(jax.tree_util.tree_leaves(full.state),
                    jax.tree_util.tree_leaves(tail.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


#: the crash-resume world the SIGKILL gate trains (importable by the
#: child process, so writer and resumer build the identical program)
CRASH_T = 24
CRASH_K = 4


def _crash_setup():
    job = _job()
    spec = _spec(job, T=CRASH_T, scenario=NAN_WORLD)
    _, plan = _faulted_plan(job, spec)
    tr = _trainer(job, guards=GuardConfig())
    return tr, plan


def _crash_child_main(snapdir):                    # pragma: no cover
    tr, plan = _crash_setup()
    snap = AsyncSnapshotter(snapdir, CRASH_K, keep=3)
    ex = PlanExecutor(tr, plan)

    def throttle(i, st, m):                        # ~0.25 s per round: the
        time.sleep(0.25)                           # parent kills mid-run

    ex.run_scan(tr.init_state(jax.random.PRNGKey(0)),
                rounds_per_launch=CRASH_K, metrics="tap",
                on_step=throttle, snapshot=snap)
    print("FINISHED", flush=True)


def test_sigkill_crash_resume_gate(tmp_path):
    """The durability acceptance gate: a subprocess training with async
    tap-mode snapshots is SIGKILLed mid-chunk; this process restores the
    newest restorable snapshot and resumes — the result is bit-for-bit
    the uninterrupted run."""
    snapdir = str(tmp_path / "crash-snaps")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (env.get("PYTHONPATH", ""),
                    os.path.dirname(os.path.abspath(__file__))) if p)
    child = subprocess.Popen(
        [sys.executable, "-c",
         "import sys; from test_faults import _crash_child_main; "
         "_crash_child_main(sys.argv[1])", snapdir],
        env=env, cwd=os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))),
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
    try:
        # wait for the first RESTORABLE snapshot, then kill -9 mid-run
        deadline = time.time() + 300
        found = None
        while time.time() < deadline:
            if child.poll() is not None:
                break
            found = AsyncSnapshotter.latest(snapdir)
            if found is not None:
                break
            time.sleep(0.05)
        assert found is not None, (
            "child produced no snapshot before finishing/deadline:\n"
            + child.communicate()[1])
        child.send_signal(signal.SIGKILL)
        child.wait(timeout=60)
    finally:
        if child.poll() is None:
            child.kill()
            child.wait(timeout=60)
    out = (child.stdout.read() or "") if child.stdout else ""
    assert "FINISHED" not in out, "child finished before the kill landed"

    r, latest = AsyncSnapshotter.latest(snapdir)
    assert 0 < r < CRASH_T, f"kill was not mid-run (snapshot round {r})"
    assert r % CRASH_K == 0                        # chunk boundary

    tr, plan = _crash_setup()
    ex = PlanExecutor(tr, plan)
    full = ex.run_scan(tr.init_state(jax.random.PRNGKey(0)),
                       rounds_per_launch=CRASH_K, metrics="none")
    restored = checkpoint.restore(latest, tr.abstract_state(),
                                  shardings=tr.state_shardings())
    assert int(restored["step"]) == r
    resumed = ex.run_scan(restored, rounds_per_launch=CRASH_K,
                          metrics="none", start_round=r)
    for a, b in zip(jax.tree_util.tree_leaves(full.state),
                    jax.tree_util.tree_leaves(resumed.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the guarded run survived its injected NaN rounds
    assert all(np.isfinite(l).all() for l in _leaves(tr, resumed.state))


# ---------------------------------------------------------------------------
# backend wiring: guards + snapshots + breaker through repro.api
# ---------------------------------------------------------------------------
def test_backend_threads_guards_snapshots_and_faults(tmp_path):
    """End-to-end through ``repro.api``: a TrainJob with guards=True on a
    faulted world trains finite, reports the snapshot count, and matches
    the eager oracle."""
    job = _job(guards=True)
    spec = _spec(job, T=8, scenario=NAN_WORLD,
                 runtime="scan", rounds_per_launch=4)
    snap = AsyncSnapshotter(str(tmp_path / "be-snaps"), 4)
    res = TrainerBackend(snapshot=snap).run(spec)
    assert res.extra["snapshots"] == 2
    assert res.extra["tripped_round"] is None
    assert np.isfinite(res.losses[np.array(
        [m["skipped"] for m in res.extra["metrics"]]) == 0.0]).all()
    res_e = TrainerBackend(runtime="eager").run(spec)
    np.testing.assert_allclose(
        res.losses, res_e.losses, **TOL)
    # breaker threading: tap-mode backend accepts one and reports the trip
    br = DivergenceBreaker(window=2, factor=2.0)
    spec2 = _spec(_job(), T=8, scenario="corrupt_receipt:k=3,scale=1e4,"
                                        "every=2,span=1",
                  runtime="scan", rounds_per_launch=2, metrics="tap")
    res2 = TrainerBackend(breaker=br).run(spec2)
    assert res2.extra["tripped_round"] == br.tripped_round
    assert br.tripped
