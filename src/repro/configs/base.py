"""Architecture + run configuration.

One :class:`ArchConfig` per assigned architecture lives in this package; the
exact dims come from the assignment table (sources cited per file).
``reduced()`` produces the smoke-test variant (≤2 layers, d_model ≤ 512,
≤4 experts) mandated by the brief.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None   # engaged for long_500k decode

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    attn_every: int = 0            # hybrid: shared attn block every k ssm layers

    # encoder-decoder (audio)
    enc_layers: int = 0
    dec_ratio: int = 4             # decoder seq = seq_len // dec_ratio
    frontend_dim: int = 0          # stubbed modality embedding dim (0 = none)

    # vlm
    n_patches: int = 0             # stub patch embeddings prepended in train
    vision_dim: int = 0

    # numerics / training
    use_flash_attention: bool = False   # Pallas kernel path (TPU target)
    use_ssd_kernel: bool = False        # Pallas SSD intra-chunk kernel
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    remat: str = "full"            # none | full

    # ---- derived -----------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_enc_dec(self) -> bool:
        return self.enc_layers > 0

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k decode (SSM state, hybrid, or SWA)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke variant of the same family: 2 layers, d_model ≤ 512, ≤4 experts."""
        d = min(self.d_model, 256)
        dh = 32
        nh = max(self.n_heads * d // self.d_model, 2)
        nh = min(max(nh, 2), d // dh)
        nkv = max(1, min(self.n_kv_heads, nh)) if self.n_kv_heads < self.n_heads else nh
        nkv = max(1, min(nkv, nh))
        while nh % nkv:
            nkv -= 1
        kw = dict(
            n_layers=2,
            d_model=d,
            n_heads=nh,
            n_kv_heads=nkv,
            d_head=dh,
            d_ff=min(self.d_ff, 512) or 0,
            vocab=min(self.vocab, 512),
        )
        if self.n_experts:
            kw.update(n_experts=4, top_k=min(self.top_k, 2),
                      n_shared_experts=min(self.n_shared_experts, 1),
                      moe_d_ff=min(self.moe_d_ff, 128))
        if self.ssm_state:
            kw.update(ssm_state=min(self.ssm_state, 32), ssm_head_dim=32,
                      ssm_chunk=16)
        if self.attn_every:
            kw.update(attn_every=1)   # 2 layers → 2 shared-attn insertions
        if self.enc_layers:
            kw.update(enc_layers=2)
        if self.n_patches:
            kw.update(n_patches=4, vision_dim=min(self.vision_dim, 64))
        if self.frontend_dim:
            kw.update(frontend_dim=min(self.frontend_dim, 32))
        if self.sliding_window:
            kw.update(sliding_window=32)
        return self.with_(**kw)


# ----------------------------------------------------------------------------
# input shapes (assigned)
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def smoke_shape(kind: str) -> InputShape:
    return {
        "train": InputShape("smoke_train", 64, 2, "train"),
        "prefill": InputShape("smoke_prefill", 64, 2, "prefill"),
        "decode": InputShape("smoke_decode", 64, 2, "decode"),
    }[kind]
