"""`repro.api` — one spec, three backends (simulator · trainer · serve).

AsGrad's unifying view (PAPER.md §3.1) is that pure/random/shuffled/FedBuff
asynchronous SGD are ONE algorithm: SGD with an arbitrary data ordering plus
delays,

    x_{t+1} = x_t − γ̃ · g_{i_t}(x_{π_t}),        γ̃ = γ / b.

This package makes "run an AsGrad experiment" a one-liner against any
execution tier::

    from repro.api import ExperimentSpec, run
    res = run(ExperimentSpec(scheduler="shuffled", timing="poisson:slow=8",
                             objective=prob, T=4000, stepsize=0.002))

Spec field → paper notation:

====================  ====================================================
``scheduler``         the job-assignment policy: which worker i_t serves
                      update t, and at which iterate π_t its job was
                      assigned (``"pure"``, ``"random"``, ``"shuffled"``,
                      ``"fedbuff:b=4"``, … over ``repro.core.REGISTRY``);
                      ``b`` is the waiting parameter (one server update per
                      b received gradients, Alg 3/5)
``timing``            worker compute-time law; together with the scheduler
                      it realises the delays τ_t = t − π_t and the
                      concurrency τ_C (Defs 1–2)
``T``                 horizon: number of received gradients (simulator),
                      server rounds (trainer), or decode steps (serve)
``stepsize``          the server stepsize γ — constant, grid-searched
                      (one shared schedule, single batched scan), or
                      delay-adaptive γ_t = γ·min(1, τ_C/(τ_t+1))
``objective``         the local functions f_i (problem object), a
                      ``TrainJob`` (pod-scale trainer), or a ``ServeJob``
``stochastic``        sample mini-batch gradients (Assumption 2) instead
                      of full local gradients ∇f_i
====================  ====================================================

Backends return a unified :class:`RunResult` (final iterate/params,
grad-norm & loss curves, realised τ_max/τ_avg/τ_C, wall-time).
"""
from .spec import (ExperimentSpec, StepsizePolicy, TrainJob, ServeJob,
                   constant, grid, delay_adaptive, parse_compact)
from .result import RunResult
from .backends import (Backend, SimulatorBackend, TrainerBackend,
                       ServeBackend, run)

__all__ = [
    "ExperimentSpec", "StepsizePolicy", "TrainJob", "ServeJob",
    "constant", "grid", "delay_adaptive", "parse_compact",
    "RunResult",
    "Backend", "SimulatorBackend", "TrainerBackend", "ServeBackend", "run",
]
