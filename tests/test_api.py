"""Tests for the unified ``repro.api`` experiment layer.

The load-bearing guarantees:

* spec strings resolve to the same scheduler/timing objects the raw core
  path builds,
* ``SimulatorBackend`` is bit-identical to raw ``build_schedule``+``replay``
  (including the batched grid search vs a per-γ Python loop),
* ``TrainerBackend``'s round masks conserve gradients: every round's mask
  row sums to ``wait_b`` for every scheduler in the registry.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.api import (ExperimentSpec, SimulatorBackend, TrainerBackend,
                       StepsizePolicy, TrainJob, constant, delay_adaptive,
                       grid, parse_compact, run)
from repro.core import (REGISTRY, TimingModel, build_schedule,
                        delay_adaptive_stepsizes, heterogeneous_speeds,
                        make_scheduler, replay, replay_grid)
from repro.objectives import LogRegProblem, QuadraticProblem, make_synthetic


def _logreg(n=8, m=40, d=30, seed=0, **kw):
    A, b = make_synthetic(1.0, 1.0, n=n, m=m, d=d, seed=seed)
    return LogRegProblem(A, b, lam=0.1, **kw)


# ---------------------------------------------------------------------------
# spec parsing
# ---------------------------------------------------------------------------
def test_parse_compact():
    assert parse_compact("fedbuff:b=4") == ("fedbuff", {"b": 4})
    assert parse_compact("poisson:slow=8") == ("poisson", {"slow": 8})
    assert parse_compact("shuffled:reshuffle=0") == ("shuffled", {"reshuffle": 0})
    assert parse_compact("pure") == ("pure", {})


def test_spec_scheduler_resolution():
    prob = _logreg()
    spec = ExperimentSpec(scheduler="fedbuff:b=4", objective=prob)
    s = spec.make_scheduler()
    assert s.name == "fedbuff" and s.wait_b == 4 and s.n == prob.n
    assert ExperimentSpec(scheduler="shuffled:reshuffle=0",
                          objective=prob).make_scheduler().reshuffle == 0
    with pytest.raises(ValueError):
        ExperimentSpec(scheduler="nonsense", objective=prob)


def test_stepsize_policy_coercion():
    assert ExperimentSpec(objective=None, n_workers=2,
                          stepsize=0.01).stepsize == constant(0.01)
    assert ExperimentSpec(objective=None, n_workers=2,
                          stepsize=(0.01, 0.02)).stepsize == grid(0.01, 0.02)
    assert StepsizePolicy.coerce("grid:0.005,0.002") == grid(0.005, 0.002)
    assert StepsizePolicy.coerce("delay_adaptive:0.05") == delay_adaptive(0.05)
    with pytest.raises(ValueError):
        StepsizePolicy("warmup", (0.1,))


def test_spec_explicit_speeds_compose_with_timing_options():
    """Explicit speeds must override slow/base, not clash with them — the
    default timing string itself carries ``slow=5``."""
    spec = ExperimentSpec(scheduler="pure", objective=None, n_workers=4,
                          speeds=(1.0, 2.0, 3.0, 4.0))
    assert np.array_equal(spec.make_timing().speeds, [1.0, 2.0, 3.0, 4.0])
    tm = ExperimentSpec(scheduler="pure", timing="poisson:slow=6",
                        objective=None, n_workers=4,
                        speeds=(1.0, 2.0, 3.0, 4.0)).make_timing()
    assert tm.pattern == "poisson"
    assert np.array_equal(tm.speeds, [1.0, 2.0, 3.0, 4.0])


def test_spec_timing_matches_raw_model():
    spec = ExperimentSpec(scheduler="pure", timing="poisson:slow=8",
                          objective=None, n_workers=6, seed=3)
    tm = spec.make_timing()
    raw = TimingModel(heterogeneous_speeds(6, 8.0), "poisson", seed=3)
    assert np.array_equal(tm.speeds, raw.speeds)
    assert tm.pattern == raw.pattern
    # identical sample streams → identical schedules downstream
    assert [tm.sample(0) for _ in range(5)] == [raw.sample(0) for _ in range(5)]


# ---------------------------------------------------------------------------
# SimulatorBackend ≡ raw build_schedule + replay (bit-identical)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheduler,b", [("pure", 1), ("fedbuff:b=4", 4),
                                         ("shuffled", 1)])
def test_simulator_backend_parity_constant(scheduler, b):
    prob = _logreg()
    T, gamma = 120, 0.004
    spec = ExperimentSpec(scheduler=scheduler, timing="poisson:slow=8",
                          objective=prob, T=T, stepsize=gamma, log_every=20,
                          seed=0)
    res = SimulatorBackend().run(spec)

    name, _ = parse_compact(scheduler)
    sched = make_scheduler(name, prob.n, b=b, seed=0)
    tm = TimingModel(heterogeneous_speeds(prob.n, 8.0), "poisson", seed=0)
    s = build_schedule(sched, tm, T)
    raw = replay(s, prob.grad_fn(), jnp.zeros(prob.d), gamma, log_every=20,
                 full_grad_fn=prob.full_grad, loss_fn=prob.loss)
    np.testing.assert_array_equal(res.x, raw.x)
    np.testing.assert_array_equal(res.xs, raw.xs)
    np.testing.assert_array_equal(res.grad_norms, raw.grad_norms)
    assert res.gamma == gamma
    assert res.trace["tau_max"] == s.tau_max()


def test_simulator_backend_parity_stochastic():
    import jax

    prob = _logreg(batch_size=10)
    spec = ExperimentSpec(scheduler="random", timing="uniform:slow=4",
                          objective=prob, T=80, stepsize=0.01,
                          stochastic=True, log_every=10, seed=5)
    res = SimulatorBackend().run(spec)
    sched = make_scheduler("random", prob.n, seed=5)
    tm = TimingModel(heterogeneous_speeds(prob.n, 4.0), "uniform", seed=5)
    s = build_schedule(sched, tm, 80)
    # spec.seed seeds the gradient-noise key stream too
    raw = replay(s, prob.grad_fn(stochastic=True), jnp.zeros(prob.d), 0.01,
                 key=jax.random.PRNGKey(5), log_every=10)
    np.testing.assert_array_equal(res.x, raw.x)
    # a different seed must change the noise stream, not just the schedule:
    # pure + fixed timing realises a seed-independent schedule, so any
    # difference below comes from the gradient-noise keys alone
    res2 = SimulatorBackend().run(
        ExperimentSpec(scheduler="pure", timing="fixed", objective=prob,
                       T=40, stepsize=0.01, stochastic=True, log_every=10,
                       seed=1))
    res3 = SimulatorBackend().run(
        ExperimentSpec(scheduler="pure", timing="fixed", objective=prob,
                       T=40, stepsize=0.01, stochastic=True, log_every=10,
                       seed=2))
    assert not np.array_equal(res2.x, res3.x)


def test_simulator_backend_delay_adaptive_wired():
    """The delay-adaptive policy must actually reach the replay (it was dead
    code before the api layer)."""
    prob = _logreg()
    # the straggler must actually deliver within T (delay > τ_C) for the
    # adaptive scale to bite: 5× slower → delays ≈ 5·(n−1) ≫ τ_C = n
    spec = ExperimentSpec(scheduler="pure", timing="fixed", objective=prob,
                          T=60, stepsize=delay_adaptive(0.05),
                          speeds=tuple([1.0] * (prob.n - 1) + [5.0]),
                          log_every=10, seed=0)
    res = SimulatorBackend().run(spec)
    s = spec.build_schedule()
    steps = delay_adaptive_stepsizes(0.05, s.delays, s.tau_c())
    raw = replay(s, prob.grad_fn(), jnp.zeros(prob.d), steps, log_every=10)
    np.testing.assert_array_equal(res.x, raw.x)
    # and it differs from the constant-stepsize run (i.e. it did something)
    const = replay(s, prob.grad_fn(), jnp.zeros(prob.d), 0.05, log_every=10)
    assert not np.array_equal(res.x, const.x)


# ---------------------------------------------------------------------------
# batched grid search ≡ per-γ loop (bit-identical), same winner
# ---------------------------------------------------------------------------
GRID = (0.005, 0.002, 0.0005)


def test_replay_grid_bit_identical_to_loop():
    prob = _logreg()
    sched = make_scheduler("shuffled", prob.n, seed=0)
    tm = TimingModel(heterogeneous_speeds(prob.n, 8.0), "poisson", seed=0)
    s = build_schedule(sched, tm, 150)
    batched = replay_grid(s, prob.grad_fn(), jnp.zeros(prob.d), GRID,
                          log_every=25, full_grad_fn=prob.full_grad)
    for g, res in zip(GRID, batched):
        solo = replay(s, prob.grad_fn(), jnp.zeros(prob.d), g, log_every=25,
                      full_grad_fn=prob.full_grad)
        np.testing.assert_array_equal(res.x, solo.x)
        np.testing.assert_array_equal(res.xs, solo.xs)
        np.testing.assert_array_equal(res.grad_norms, solo.grad_norms)


def test_grid_selection_matches_legacy_protocol():
    """The backend's winner must equal the old benchmarks/common.py loop:
    rebuild-schedule-per-γ, score = tail mean + 0.5·tail std, first min."""
    prob = _logreg(n=6, m=30, d=20, seed=1)
    T = 200
    spec = ExperimentSpec(scheduler="shuffled", timing="poisson:slow=8",
                          objective=prob, T=T, stepsize=grid(*GRID),
                          log_every=20, seed=0)
    res = SimulatorBackend().run(spec)

    best = None
    for gamma in GRID:
        sched = make_scheduler("shuffled", prob.n, seed=0)
        tm = TimingModel(heterogeneous_speeds(prob.n, 8.0), "poisson", seed=0)
        s = build_schedule(sched, tm, T)
        r = replay(s, prob.grad_fn(), jnp.zeros(prob.d), gamma, log_every=20,
                   full_grad_fn=prob.full_grad)
        score = float(np.mean(r.grad_norms[-3:])) + \
            0.5 * float(np.std(r.grad_norms[-5:]))
        if best is None or score < best[0]:
            best = (score, gamma, r)
    _, legacy_gamma, legacy = best
    assert res.gamma == legacy_gamma
    np.testing.assert_array_equal(res.grad_norms, legacy.grad_norms)
    np.testing.assert_array_equal(res.x, legacy.x)
    assert set(res.grid) == set(GRID)


def test_grid_requires_full_grad():
    prob = QuadraticProblem(np.random.default_rng(0).normal(size=(4, 3)))

    class NoFullGrad:
        n, d = prob.n, prob.d
        grad_fn = staticmethod(prob.grad_fn)

    spec = ExperimentSpec(scheduler="pure", objective=NoFullGrad(), T=20,
                          stepsize=grid(0.1, 0.01))
    with pytest.raises(ValueError, match="full_grad"):
        SimulatorBackend().run(spec)


# ---------------------------------------------------------------------------
# TrainerBackend mask consistency
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_trainer_masks_row_sums_equal_wait_b(name):
    """Round q aggregates exactly ``wait_b`` gradients for EVERY scheduler:
    the participation masks must conserve that count."""
    n, rounds = 8, 25
    b = 4 if name in ("pure_waiting", "fedbuff", "minibatch") else 1
    spec = ExperimentSpec(scheduler=f"{name}:b={b}" if b > 1 else name,
                          timing="poisson:slow=6", objective=None,
                          n_workers=n, T=rounds, stepsize=0.01, seed=0)
    masks, schedule = TrainerBackend.masks_for(spec)
    wait_b = spec.make_scheduler().wait_b
    assert masks.shape == (rounds, n)
    assert np.all(masks.sum(axis=1) == wait_b)
    assert np.all(masks >= 0)
    # and the masks agree with the realised schedule's per-round receipts
    for q in range(rounds):
        w, c = np.unique(schedule.workers[q * wait_b:(q + 1) * wait_b],
                         return_counts=True)
        np.testing.assert_array_equal(masks[q, w], c)
        assert masks[q, np.setdiff1d(np.arange(n), w)].sum() == 0


def test_result_json_roundtrip_simulator_grid():
    """Archived runs must round-trip: curves and grid arrays exactly
    (dtype-tagged lists), spec/schedule as documented summaries."""
    from repro.api import RunResult

    prob = _logreg()
    spec = ExperimentSpec(scheduler="shuffled", timing="poisson:slow=8",
                          objective=prob, T=100, stepsize=grid(*GRID),
                          log_every=20, seed=0)
    res = SimulatorBackend().run(spec)
    r2 = RunResult.from_json(res.to_json())
    assert r2.backend == "simulator" and r2.gamma == res.gamma
    np.testing.assert_array_equal(r2.x, res.x)
    np.testing.assert_array_equal(r2.losses, res.losses)
    np.testing.assert_array_equal(r2.grad_norms, res.grad_norms)
    assert r2.grad_norms.dtype == res.grad_norms.dtype
    assert set(r2.grid) == set(GRID)            # float keys restored
    for g in GRID:
        np.testing.assert_array_equal(r2.grid[g]["grad_norms"],
                                      res.grid[g]["grad_norms"])
        assert r2.grid[g]["score"] == res.grid[g]["score"]
    assert r2.trace == {k: v for k, v in res.trace.items()}
    # schedule comes back as its τ summary, not a live object
    assert r2.schedule["tau_max"] == res.schedule.tau_max()
    assert r2.schedule["wait_b"] == res.schedule.wait_b
    # spec comes back as a tagged field dict
    assert r2.spec["__dataclass__"] == "ExperimentSpec"
    assert r2.spec["scheduler"] == "shuffled"


def test_result_json_version_mismatch_rejected():
    """An archive from a different payload layout must fail loudly, not
    deserialize into silently-wrong fields."""
    import json
    from repro.api import RunResult

    res = RunResult(spec=None, backend="simulator",
                    losses=np.arange(3, dtype=np.float64))
    good = res.to_json()
    assert RunResult.from_json(good).backend == "simulator"
    for bad_version in (0, 999, None, "1"):
        payload = json.loads(good)
        payload["version"] = bad_version
        if bad_version is None:
            del payload["version"]
        with pytest.raises(ValueError, match="version"):
            RunResult.from_json(json.dumps(payload))


def test_result_json_big_leaves_become_stubs():
    """Arrays above the 64k-element cap archive as (shape, dtype, l2)
    summary stubs — the stub must survive the round trip (and small
    arrays in the same tree must still round-trip exactly)."""
    from repro.api import RunResult
    from repro.api.result import _MAX_ARRAY_ELEMS

    big = np.ones((_MAX_ARRAY_ELEMS + 1,), np.float32)
    small = np.arange(7, dtype=np.int32)
    res = RunResult(spec=None, backend="trainer",
                    extra={"big": big, "small": small})
    r2 = RunResult.from_json(res.to_json())
    stub = r2.extra["big"]
    assert set(stub) == {"__array_summary__"}
    summ = stub["__array_summary__"]
    assert summ["shape"] == [_MAX_ARRAY_ELEMS + 1]
    assert summ["dtype"] == "float32"
    np.testing.assert_allclose(summ["l2"], np.sqrt(_MAX_ARRAY_ELEMS + 1))
    np.testing.assert_array_equal(r2.extra["small"], small)
    assert r2.extra["small"].dtype == np.int32
    # exactly at the cap: still exact, not a stub
    at_cap = RunResult(spec=None, backend="trainer",
                       extra={"edge": np.zeros(_MAX_ARRAY_ELEMS,
                                               np.float32)})
    r3 = RunResult.from_json(at_cap.to_json())
    assert isinstance(r3.extra["edge"], np.ndarray)
    assert r3.extra["edge"].shape == (_MAX_ARRAY_ELEMS,)


def test_result_json_grid_lane_shape_roundtrip():
    """The grid-lane RunResult layout (per-γ curve dict + lane provenance
    in extra) archives and restores without a live trainer run."""
    from repro.api import RunResult

    gammas = (3e-3, 1.5e-3)
    grid_info = {g: {"losses": np.linspace(4.6, 4.0, 5),
                     "grad_norms": np.linspace(1.0, 0.5, 5),
                     "score": 4.0 + i}
                 for i, g in enumerate(gammas)}
    res = RunResult(spec=None, backend="trainer",
                    losses=grid_info[gammas[0]]["losses"],
                    gamma=gammas[0], grid=grid_info,
                    extra={"grid_lane": True, "n_grid": 2,
                           "runtime": "scan", "metrics_mode": "chunk",
                           "launches": 2, "host_syncs": 1,
                           "tap_events": 0})
    r2 = RunResult.from_json(res.to_json())
    assert set(r2.grid) == set(gammas)          # float keys restored
    for g in gammas:
        np.testing.assert_array_equal(r2.grid[g]["losses"],
                                      grid_info[g]["losses"])
    assert r2.extra["grid_lane"] is True
    assert r2.extra["n_grid"] == 2 and r2.extra["tap_events"] == 0


def test_spec_carries_runtime_choice():
    """One spec object serves every tier: runtime fields parse/validate on
    the spec, and non-trainer backends simply ignore them."""
    prob = _logreg()
    spec = ExperimentSpec(scheduler="pure", objective=prob, T=30,
                          stepsize=0.01, log_every=10,
                          runtime="eager", rounds_per_launch=4,
                          metrics="tap")
    assert spec.runtime == "eager" and spec.rounds_per_launch == 4
    assert spec.metrics == "tap"
    res = SimulatorBackend().run(spec)          # ignored, not rejected
    assert res.backend == "simulator"
    with pytest.raises(ValueError, match="runtime"):
        ExperimentSpec(scheduler="pure", objective=prob, runtime="jitless")
    with pytest.raises(ValueError, match="metrics"):
        ExperimentSpec(scheduler="pure", objective=prob, metrics="csv")


def test_run_dispatches_on_objective():
    prob = _logreg()
    res = run(ExperimentSpec(scheduler="rr", objective=prob, T=40,
                             stepsize=0.01, log_every=10))
    assert res.backend == "simulator"
    assert res.trace["tau_max"] == 0   # SGD-RR is delay-free (§C.3.4)


@pytest.mark.slow
def test_trainer_backend_smoke():
    """Production tier end-to-end: 3 rounds of the reduced transformer."""
    res = run(ExperimentSpec(
        scheduler="shuffled", timing="poisson:slow=8",
        objective=TrainJob(arch="qwen2-0.5b", global_batch=8, seq_len=16),
        T=3, n_workers=4, stepsize=5e-3, seed=0))
    assert res.backend == "trainer"
    assert len(res.losses) == 3
    assert np.all(np.isfinite(res.losses))
    assert res.extra["masks"].shape == (3, 4)
