"""Compiled whole-run executor: K rounds per XLA launch via ``lax.scan``.

The eager dispatch loop pays three per-round costs the hardware never asked
for: a Python dispatch of the jitted step, a host-built batch shipped to
device, and a device→host sync to read the metrics.  This module removes
all three — the :class:`RunPlan` is device-resident, batches are
synthesised on device from the plan's folded PRNG keys, and metrics
accumulate into an on-device ``(K, n_metrics)`` buffer (the stacked ys of
the scan) that crosses to host ONCE per chunk.

``rounds_per_launch`` (K) is the dispatch-vs-control-granularity trade-off:

* K = 1 degenerates to eager dispatch (one launch per round),
* K = rounds is one launch for the whole run (no callbacks until the end),
* intermediate K keeps ``on_step`` callbacks and checkpoint barriers firing
  every K rounds while amortising dispatch K×.

:func:`run_eager` is the same plan executed one round per launch — the
parity oracle the scan executor is gated against (same step function, same
device-synthesised batches, same plan slices; only the dispatch differs).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from .plan import RunPlan

#: fixed metric order of the on-device accumulator row; mirrors the dict
#: returned by ``AsyncTrainer.train_step_fn``
METRICS = ("loss", "ce", "aux", "grad_norm", "participation")


@dataclasses.dataclass
class ExecResult:
    """Final carried state + per-round metric curves (host numpy)."""

    state: object
    metrics: dict            # name -> (rounds,) np.ndarray, keys = METRICS
    launches: int = 0        # XLA dispatches issued
    host_syncs: int = 0      # device→host metric transfers

    @property
    def rows(self) -> list:
        """Metrics as one dict per round (the eager loop's legacy shape)."""
        n = len(next(iter(self.metrics.values()))) if self.metrics else 0
        return [{k: float(v[i]) for k, v in self.metrics.items()}
                for i in range(n)]


def make_batch_fn(plan: RunPlan, cfg) -> Callable:
    """``batch_of(key) -> batch dict``, entirely on device.

    Tokens: inverse-CDF Zipf draws (``searchsorted`` on the plan's
    cumulative pmf) pushed through each group's vocab permutation — the
    same marginal law and heterogeneity structure as the host
    ``HeterogeneousTokenPipeline``, as a pure jittable function of the
    round key.  Non-token modalities (vision patches / audio frames) are
    the same stubbed normal draws the host path used, keyed per-modality
    via ``fold_in``.
    """
    import jax
    import jax.numpy as jnp
    from ..models import batch_specs

    specs = batch_specs(cfg, plan.global_batch, plan.seq_len)
    cdf = jnp.asarray(plan.token_cdf)
    perms = jnp.asarray(plan.group_perms)
    per = plan.global_batch // plan.n_groups
    gidx = jnp.repeat(jnp.arange(plan.n_groups), per)

    def batch_of(key):
        out = {}
        for j, (k, sp) in enumerate(sorted(specs.items())):
            kj = jax.random.fold_in(key, j)
            if sp.dtype == "int32":          # tokens (possibly shortened)
                u = jax.random.uniform(kj, (plan.global_batch, sp.shape[1]))
                ranks = jnp.clip(jnp.searchsorted(cdf, u), 0,
                                 cdf.shape[0] - 1).astype(jnp.int32)
                out[k] = perms[gidx[:, None], ranks]
            else:                            # stubbed modality embeddings
                out[k] = jax.random.normal(kj, sp.shape, jnp.float32)
        return out

    return batch_of


def _metrics_row(m: dict):
    import jax.numpy as jnp
    return jnp.stack([jnp.asarray(m[k], jnp.float32) for k in METRICS])


def _chunk_bounds(rounds: int, rounds_per_launch: int, start: int):
    k = max(int(rounds_per_launch), 1)
    lo = start
    while lo < rounds:
        hi = min(lo + k, rounds)
        yield lo, hi
        lo = hi



class PlanExecutor:
    """Holds the compiled artifacts for one (trainer × plan): build once,
    run many.  The jitted chunk function is cached on the instance, so
    repeated runs (benchmark warm timings, grid restarts, resumed runs)
    pay tracing/compilation only on first use per chunk length — a fresh
    closure per run would silently recompile every time.
    """

    def __init__(self, trainer, plan: RunPlan, *, donate: bool = True):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        self.trainer = trainer
        self.plan = plan
        self.donate = donate
        self._batch_of = make_batch_fn(plan, trainer.cfg)
        self._repl = NamedSharding(trainer.mesh, P())   # plan slices
        self._eager = None           # lazily built parity-oracle pair

        step = trainer.train_step_fn()
        batch_of = self._batch_of
        repl = self._repl

        # only an ADAPTIVE plan carries a real per-round γ-scale; for a
        # neutral plan the step is called 3-arg so the trainer's own
        # static AsyncConfig.delay_adaptive rule stays in charge (an
        # explicit all-ones scale would silently override it)
        adaptive = plan.adaptive

        def chunk(state, masks, keys, scales):
            def body(st, xs):
                mask, key, scale = xs
                # pin the synthesised batch to replicated BEFORE the
                # step's own constraints reshard it: otherwise GSPMD
                # propagates the data-axis sharding back into the RNG
                # ops, and legacy (non-partitionable) threefry generates
                # DIFFERENT bits per shard than the replicated generation
                # the eager oracle uses — 2% loss divergence, not FMA
                # noise
                batch = jax.tree_util.tree_map(
                    lambda x: jax.lax.with_sharding_constraint(x, repl),
                    batch_of(key))
                st, m = step(st, batch, mask, scale) if adaptive \
                    else step(st, batch, mask)
                return st, _metrics_row(m)

            return jax.lax.scan(body, state, (masks, keys, scales))

        state_sh = trainer.state_shardings()
        self._chunk_jit = jax.jit(
            chunk,
            in_shardings=(state_sh, repl, repl, repl),
            out_shardings=(state_sh, None),
            donate_argnums=(0,) if donate else ())

    # ------------------------------------------------------------------ scan
    def run_scan(self, state, *, rounds_per_launch: int = 8,
                 on_step: Optional[Callable] = None,
                 start_round: int = 0) -> ExecResult:
        """Execute plan rounds ``[start_round, rounds)``, K per launch.

        One XLA launch covers K = ``rounds_per_launch`` rounds; the
        carried state is donated launch-to-launch (the chunk's input
        buffers are reused, so state never doubles in memory).
        ``on_step(i, state, metrics_i)`` fires for every completed
        round — but only at chunk boundaries, with the END-of-chunk state
        (checkpoint barriers therefore land on multiples of K; align
        ``ckpt_every`` with K for exact-resume semantics).  A ragged tail
        (``rounds % K != 0``) costs at most one extra compile for the
        remainder length.

        ``start_round > 0`` resumes mid-plan: the data keys are a pure
        function of (seed, round), so a restored run regenerates the
        identical batch stream.
        """
        plan = self.plan
        rows, launches = [], 0
        for lo, hi in _chunk_bounds(plan.rounds, rounds_per_launch,
                                    start_round):
            state, ms = self._chunk_jit(state, *plan.device_slices(lo, hi))
            ms = np.asarray(ms)           # ONE host sync per chunk
            rows.append(ms)
            launches += 1
            if on_step is not None:
                for i in range(lo, hi):
                    on_step(i, state,
                            {k: float(v)
                             for k, v in zip(METRICS, ms[i - lo])})
        all_ms = np.concatenate(rows, axis=0) if rows else \
            np.zeros((0, len(METRICS)), np.float32)
        return ExecResult(
            state=state,
            metrics={k: all_ms[:, j] for j, k in enumerate(METRICS)},
            launches=launches, host_syncs=launches)

    # ----------------------------------------------------------------- eager
    def run_eager(self, state, *, on_step: Optional[Callable] = None,
                  start_round: int = 0) -> ExecResult:
        """The parity oracle: the same plan, one launch + one host sync
        per round (the pre-runtime dispatch loop, kept as the semantic
        reference)."""
        import jax
        import jax.numpy as jnp

        plan = self.plan
        if self._eager is None:
            self._eager = (
                jax.jit(self._batch_of),
                self.trainer.jit_train_step(
                    (plan.global_batch, plan.seq_len),
                    donate=self.donate,
                    with_delay_scale=plan.adaptive))
        batch_of, step = self._eager
        rows = []
        for i in range(start_round, plan.rounds):
            key = jnp.asarray(plan.data_keys[i])
            args = (state, batch_of(key), jnp.asarray(plan.masks[i]))
            if plan.adaptive:       # neutral plans: the trainer's own
                args += (jnp.float32(plan.delay_scales[i]),)  # static rule
            state, m = step(*args)
            row = {k: float(m[k]) for k in METRICS}  # host sync per round
            rows.append([row[k] for k in METRICS])
            if on_step is not None:
                on_step(i, state, row)
        all_ms = np.asarray(rows, np.float32) if rows else \
            np.zeros((0, len(METRICS)), np.float32)
        n = all_ms.shape[0]
        # per round the eager loop issues TWO dispatches: the batch-
        # synthesis jit plus the step jit (the scan executor fuses
        # synthesis into the chunk, so its count is launches-per-chunk)
        return ExecResult(
            state=state,
            metrics={k: all_ms[:, j] for j, k in enumerate(METRICS)},
            launches=2 * n, host_syncs=n)


def run_scan(trainer, plan: RunPlan, state, *, rounds_per_launch: int = 8,
             on_step: Optional[Callable] = None, start_round: int = 0,
             donate: bool = True) -> ExecResult:
    """One-shot convenience over :meth:`PlanExecutor.run_scan` (compiles
    fresh; hold a :class:`PlanExecutor` to reuse compiled chunks)."""
    return PlanExecutor(trainer, plan, donate=donate).run_scan(
        state, rounds_per_launch=rounds_per_launch, on_step=on_step,
        start_round=start_round)


def run_eager(trainer, plan: RunPlan, state, *,
              on_step: Optional[Callable] = None, start_round: int = 0,
              donate: bool = True) -> ExecResult:
    """One-shot convenience over :meth:`PlanExecutor.run_eager`."""
    return PlanExecutor(trainer, plan, donate=donate).run_eager(
        state, on_step=on_step, start_round=start_round)


RUNTIMES = {"scan": run_scan, "eager": run_eager}


def execute(trainer, plan: RunPlan, state, *, runtime: str = "scan",
            rounds_per_launch: int = 8, **kw) -> ExecResult:
    """Dispatch on ``runtime`` (`"scan"` | `"eager"`)."""
    if runtime not in RUNTIMES:
        raise ValueError(
            f"unknown runtime {runtime!r}; want one of {sorted(RUNTIMES)}")
    if runtime == "scan":
        kw["rounds_per_launch"] = rounds_per_launch
    return RUNTIMES[runtime](trainer, plan, state, **kw)
