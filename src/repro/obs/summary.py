"""Human rendering of a :meth:`repro.obs.Recorder.summary` dict."""
from __future__ import annotations

from typing import Optional


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def render_summary(summary: dict, trace: Optional[dict] = None,
                   title: str = "observability summary") -> str:
    """ASCII table of a run's obs summary: time-in-phase breakdown,
    dispatch counters, histogram summaries, and (when ``trace`` — the
    ``RunResult.trace`` τ-statistics dict — is given) the delay stats
    AsGrad's rates are written in.  Works equally on a live
    ``recorder.summary()`` and on ``extra["obs"]`` restored from an
    archived ``RunResult`` JSON.
    """
    wall = float(summary.get("wall_s", 0.0))
    lines = [title, "=" * len(title)]

    phases = summary.get("phases") or {}
    if phases:
        lines.append(f"{'phase':<22} {'count':>7} {'total_s':>9} "
                     f"{'mean_ms':>9} {'% wall':>7}")
        for name, e in sorted(phases.items(),
                              key=lambda kv: -kv[1]["total_s"]):
            pct = 100.0 * e["total_s"] / wall if wall > 0 else 0.0
            lines.append(f"{name:<22} {e['count']:>7} {e['total_s']:>9.4f} "
                         f"{e['mean_ms']:>9.3f} {pct:>6.1f}%")
    else:
        lines.append("(no spans recorded)")

    counters = summary.get("counters") or {}
    if counters:
        lines.append("")
        lines.append("counters: " + "  ".join(
            f"{k}={_fmt(v)}" for k, v in sorted(counters.items())))
    rounds = counters.get("rounds") or summary.get("rounds")
    if rounds and wall > 0:
        lines.append(f"throughput: {float(rounds) / wall:.2f} rounds/s "
                     f"over {wall:.3f}s")

    hists = summary.get("hists") or {}
    if hists:
        lines.append("")
        lines.append(f"{'histogram':<22} {'count':>7} {'p50':>9} "
                     f"{'p95':>9} {'max':>9}")
        for name, h in sorted(hists.items()):
            lines.append(f"{name:<22} {h['count']:>7} "
                         f"{_fmt(h['p50']):>9} {_fmt(h['p95']):>9} "
                         f"{_fmt(h['max']):>9}")

    if trace:
        keys = ("tau_max", "tau_avg", "tau_c", "wait_b", "T")
        stats = "  ".join(f"{k}={_fmt(trace[k])}" for k in keys
                          if k in trace)
        if stats:
            lines.append("")
            lines.append("schedule: " + stats)
    return "\n".join(lines)
