"""HLO-text cost model with while-loop trip-count propagation.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE — with
scan-over-layers that undercounts a 64-layer model by 64×.  This module
parses the optimized (post-SPMD-partitioning) HLO text and computes:

* ``dot_flops``  — 2 · |result| · K per dot/convolution, × loop trip counts
  (matmuls dominate these models by orders of magnitude),
* ``hbm_bytes``  — Σ over top-level instructions of (operand + result) buffer
  bytes, × trip counts.  Fusion bodies are *not* descended into for traffic
  (a fusion reads its operands and writes its result once — exactly the HBM
  model we want); they ARE descended into for dot flops,
* ``collective_bytes`` — Σ operand bytes of all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute / collective-broadcast,
  × trip counts.  These are *per-device* bytes (the module is the SPMD
  per-device program); the roofline divides by per-link bandwidth directly.

Operands are printed as name references in modern HLO text; shapes are
resolved through a module-wide symbol table.  Trip counts come from the
largest integer constant in the while condition computation (standard
counted-loop shape); unknown loops default to 1 and are flagged.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"%([\w\.\-]+)")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast")
_SKIP_TRAFFIC = {
    "tuple", "get-tuple-element", "parameter", "constant", "while",
    "conditional", "bitcast", "copy-start", "copy-done", "after-all",
    "partition-id", "replica-id", "iota", "call",
    # layout/dtype ops that fuse into neighbours on TPU; counting them
    # (plus XLA:CPU's f32-upcast converts for bf16 dots) inflates the
    # memory term several-fold relative to real TPU HBM traffic
    "reshape", "broadcast", "convert", "copy", "transpose",
}

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")


def _split_instr(line: str):
    """Robustly split '%name = <type> opcode(<operands>)<attrs>'.

    Handles tuple result types with /*index=N*/ comments and parenthesised
    attrs (e.g. replica_groups=[4,2]<=[2,4]T(1,0)) that defeat one-shot
    regexes.  Returns (name, type_text, opcode, operands, attrs) or None.
    """
    m = _DEF_RE.match(line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2)
    depth = 0
    i = 0
    opcode = None
    while i < len(rest):
        c = rest[i]
        if c == "(":
            j = i - 1
            while j >= 0 and (rest[j].isalnum() or rest[j] in "-_"):
                j -= 1
            ident = rest[j + 1:i]
            if depth == 0 and ident and not ident[0].isdigit():
                opcode = ident
                type_text = rest[:j + 1]
                break
            depth += 1
        elif c == ")":
            depth -= 1
        i += 1
    if opcode is None:
        return None
    # balanced operand region
    k = i
    d = 0
    while k < len(rest):
        if rest[k] == "(":
            d += 1
        elif rest[k] == ")":
            d -= 1
            if d == 0:
                break
        k += 1
    operands = rest[i + 1:k]
    attrs = rest[k + 1:]
    return name, type_text, opcode, operands, attrs


def _shape_text_bytes(text: str) -> int:
    """Bytes of all dtype[dims] shapes appearing in a type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * b
    return total


def _first_shape_dims(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return [int(x) for x in m.group(1 + 1).split(",")] if m.group(2) else []


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_text: str
    operand_names: list
    attrs: str
    line: str


@dataclasses.dataclass
class CostResult:
    dot_flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_breakdown: dict
    n_while: int
    unknown_trip_loops: int

    def as_dict(self):
        return {
            "dot_flops": self.dot_flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_breakdown": dict(self.collective_breakdown),
            "n_while": self.n_while,
            "unknown_trip_loops": self.unknown_trip_loops,
        }


def parse_module(hlo: str):
    """→ (computations: name → [Instr], entry_name, symbols: name → type text)."""
    comps: dict[str, list[Instr]] = {}
    symbols: dict[str, str] = {}
    entry = None
    cur = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if stripped.endswith("{") and "->" in stripped:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            cur = m.group(1) if m else f"comp{len(comps)}"
            comps[cur] = []
            if stripped.startswith("ENTRY"):
                entry = cur
            # computation parameters carry shapes in the header
            hdr = stripped[stripped.find("(") + 1: stripped.rfind("->")]
            for pm in re.finditer(r"([\w\.\-]+):\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\]))",
                                  hdr):
                symbols.setdefault(pm.group(1), pm.group(2))
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        parts = _split_instr(line)
        if parts:
            nm, type_text, opcode, operands, attrs = parts
            ins = Instr(name=nm, result_text=type_text, opcode=opcode,
                        operand_names=_NAME_RE.findall(operands),
                        attrs=attrs, line=line)
            comps[cur].append(ins)
            symbols[ins.name] = ins.result_text
    return comps, entry, symbols


def _callees(instr: Instr):
    out = []
    for key in ("calls", "to_apply", "body", "condition"):
        for m in re.finditer(key + r"=%?([\w\.\-]+)", instr.attrs):
            out.append((m.group(1), key))
    return out


def _find_trip_count(cond_instrs):
    best = None
    for ins in cond_instrs:
        if ins.opcode == "constant" and ins.result_text.startswith(("s32", "u32", "s64", "u64")):
            m = re.search(r"constant\((-?\d+)\)", ins.line)
            if m:
                v = int(m.group(1))
                if best is None or v > best:
                    best = v
    return best


def analyze(hlo: str) -> CostResult:
    comps, entry, symbols = parse_module(hlo)
    if entry is None and comps:
        entry = max(comps, key=lambda c: len(comps[c]))
    multipliers: dict[str, float] = defaultdict(float)
    unknown = [0]
    n_while = [0]

    def op_bytes(ins: Instr) -> int:
        return sum(_shape_text_bytes(symbols.get(nm, "")) for nm in ins.operand_names)

    def visit(name: str, mult: float):
        if name not in comps:
            return
        multipliers[name] += mult
        for ins in comps[name]:
            if ins.opcode == "while":
                n_while[0] += 1
                body = cond = None
                for nm, kind in _callees(ins):
                    if kind == "body":
                        body = nm
                    elif kind == "condition":
                        cond = nm
                trip = _find_trip_count(comps.get(cond, [])) if cond else None
                if trip is None or trip <= 0:
                    trip = 1
                    unknown[0] += 1
                if body:
                    visit(body, mult * trip)
                if cond:
                    visit(cond, mult * (trip + 1))
            else:
                for nm, _ in _callees(ins):
                    visit(nm, mult)

    if entry:
        visit(entry, 1.0)

    dot_flops = 0.0
    hbm = 0.0
    coll = 0.0
    breakdown: dict[str, float] = defaultdict(float)
    for name, instrs in comps.items():
        m = multipliers.get(name, 0.0)
        if m == 0.0:
            continue
        is_fusion_body = "fused" in name or name.startswith("wrapped_")
        for ins in instrs:
            if ins.opcode == "dot":
                res = _SHAPE_RE.search(ins.result_text)
                out_elems = 1
                if res and res.group(2):
                    for d in res.group(2).split(","):
                        out_elems *= int(d)
                lhs_text = symbols.get(ins.operand_names[0], "") if ins.operand_names else ""
                lm = _SHAPE_RE.search(lhs_text)
                lhs_dims = ([int(x) for x in lm.group(2).split(",")]
                            if lm and lm.group(2) else [])
                mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
                k = 1
                if mm and lhs_dims:
                    for idx in mm.group(1).split(","):
                        if idx:
                            k *= lhs_dims[int(idx)]
                elif lhs_dims:
                    k = lhs_dims[-1]
                dot_flops += m * 2.0 * out_elems * k
            elif ins.opcode == "convolution":
                res = _SHAPE_RE.search(ins.result_text)
                out_elems = 1
                if res and res.group(2):
                    for d in res.group(2).split(","):
                        out_elems *= int(d)
                ker = 1
                if len(ins.operand_names) > 1:
                    km = _SHAPE_RE.search(symbols.get(ins.operand_names[1], ""))
                    if km and km.group(2):
                        for d in km.group(2).split(","):
                            ker *= int(d)
                dot_flops += m * 2.0 * out_elems * ker
            base = next((c for c in _COLLECTIVES if ins.opcode == c
                         or ins.opcode.startswith(c + "-")), None)
            if base:
                nbytes = op_bytes(ins)
                coll += m * nbytes
                breakdown[base] += m * nbytes
            if not is_fusion_body and ins.opcode not in _SKIP_TRAFFIC:
                hbm += m * (op_bytes(ins) + _shape_text_bytes(ins.result_text))
    return CostResult(dot_flops=dot_flops, hbm_bytes=hbm,
                      collective_bytes=coll, collective_breakdown=breakdown,
                      n_while=n_while[0], unknown_trip_loops=unknown[0])
